"""Quickstart: federated-train a tiny char-LM with FedShuffle, then serve it.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs.base import FLConfig
from repro.configs.paper_tasks import CHARLM_TINY
from repro.data.federated import FederatedPipeline, Population
from repro.data.tasks import CharLMTask
from repro.fed.losses import make_loss
from repro.fed.train_loop import train
from repro.launch.serve import generate
from repro.models.model import build_model


def main():
    # 1. an imbalanced federated population (log-normal |D_i|) with
    #    client-skewed char distributions — the paper's regime
    fl = FLConfig(
        num_clients=8, cohort_size=4, sampling="uniform",   # partial participation
        epochs=2, local_batch=2,                            # local RR epochs
        algorithm="fedshuffle",                             # the paper's recipe
        local_lr=1.0, server_lr=1.0, server_opt="mvr",      # + practical MVR momentum
        imbalance="lognormal", mean_samples=6, seed=0,
    )
    task = CharLMTask(vocab=CHARLM_TINY.vocab, seq_len=32, num_clients=fl.num_clients)
    pipeline = FederatedPipeline(task, Population.build(fl), fl)
    print(f"client dataset sizes: {pipeline.population.sizes.tolist()}")

    # 2. model + federated training (30 rounds)
    model = build_model(CHARLM_TINY)
    params = model.init(jax.random.PRNGKey(0))
    result = train(make_loss(model), params, pipeline, fl, rounds=30,
                   name="quickstart", log_every=10)

    # 3. serve the trained global model (prefill + autoregressive decode)
    prompts = jnp.zeros((2, 8), jnp.int32)
    out = generate(model, result.state.params, prompts, steps=12, cache_len=24,
                   temperature=0.8)
    print("generated:", out.tolist())


if __name__ == "__main__":
    main()
