"""Byzantine-robust aggregation, end to end: 20% of the fleet sign-flips
its updates at 10x scale — plain FedAvg-style weighted averaging is pulled
far off the optimum (or straight into divergence), while the same run with
``aggregator="trimmed_mean"`` lands inside the attack-free loss envelope.

    PYTHONPATH=src python examples/robust_aggregation.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig
from repro.data.federated import FederatedPipeline, Population
from repro.data.tasks import DuplicatedQuadraticTask
from repro.fed.losses import make_quadratic_loss
from repro.fed.robust import adversary_mask
from repro.fed.rounds import as_device_batch, build_round_step
from repro.fed.strategy import bind_strategy, strategy_for

N, ROUNDS, SEED = 10, 400, 2   # seed 2 draws exactly 2/10 adversaries


def run(task, loss_fn, **robust_kw):
    fl = FLConfig(num_clients=N, cohort_size=N, sampling="full", epochs=1,
                  local_batch=1, algorithm="fedshuffle", local_lr=0.05,
                  server_opt="sgd", seed=SEED, **robust_kw)
    pipe = FederatedPipeline(task, Population.build(fl, sizes=task.sizes()), fl)
    strategy = bind_strategy(strategy_for(fl), fl, loss_fn, num_clients=N)
    state = strategy.init({"x": jnp.zeros(N)})
    step = jax.jit(build_round_step(loss_fn, strategy, fl, num_clients=N))
    for r in range(ROUNDS):
        state, mets = step(state, as_device_batch(pipe.round_batch(r)))
    x = np.asarray(state.params["x"])
    diverged = not np.all(np.isfinite(x)) or np.abs(x).max() > 1e6
    return x, float("inf") if diverged else task.loss_np(x), mets


def main():
    task = DuplicatedQuadraticTask(copies=(1,) * N)
    loss_fn = make_quadratic_loss(N)
    adv = np.nonzero(adversary_mask(SEED, np.arange(N, dtype=np.uint32),
                                    0.2, xp=np))[0]
    print(f"{N} clients, adversaries (sign_flip x10): clients {adv.tolist()}\n")

    attack = dict(attack="sign_flip", attack_frac=0.2, attack_scale=10.0)
    runs = {
        "attack-free     / mean": {},
        "under attack    / mean": attack,
        "under attack    / trimmed_mean": {**attack, "aggregator": "trimmed_mean",
                                           "trim_frac": 0.25},
        "under attack    / coordinate_median": {**attack,
                                                "aggregator": "coordinate_median"},
        "under attack    / mean + quarantine": {**attack, "guard": "full"},
    }
    losses = {}
    for name, kw in runs.items():
        x, losses[name], _ = run(task, loss_fn, **kw)
        dist = float(np.linalg.norm(x - task.optimum()))
        print(f"{name:38s} loss={losses[name]:10.4f}  |x - x*|={dist:8.4f}")

    clean = losses["attack-free     / mean"]
    broken = losses["under attack    / mean"]
    healed = losses["under attack    / trimmed_mean"]
    # the robustness contract this example demonstrates (and CI re-checks in
    # benchmarks/bench_robust.py's quality arm): the attack must actually
    # hurt the plain mean, and trimming must recover the clean envelope
    assert broken > 10.0 * clean, (broken, clean)
    assert healed < 1.5 * clean, (healed, clean)
    print("\ntrimmed_mean recovered the attack-free loss envelope; "
          "plain mean did not.")


if __name__ == "__main__":
    main()
