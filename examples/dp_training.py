"""DP-FedShuffle end to end: the privacy/utility trade-off on one screen.

Trains the duplicated-quadratic task at three Gaussian noise multipliers
(plus a non-private baseline) and prints, per run, the RDP accountant's
cumulative eps(delta) next to the final evaluation loss — the curve every
DP paper plots, reproduced in a few seconds on CPU:

    PYTHONPATH=src python examples/dp_training.py

Also demonstrated: the clipping telemetry (``dp_clipped_frac`` — how often
the per-client L2 bound actually bites) and the secure-aggregation layer
composing with DP (``secagg="pairwise"``: the server only ever sees the
blinded modular sum, and the trajectory is unchanged up to the fixed-point
grid).
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig
from repro.data.federated import FederatedPipeline, Population
from repro.data.tasks import DuplicatedQuadraticTask
from repro.fed.losses import make_quadratic_loss
from repro.fed.train_loop import train

ROUNDS = 300
TASK = DuplicatedQuadraticTask(copies=(1, 2, 3))
LOSS = make_quadratic_loss(3)


def run(noise_mult, secagg="off"):
    dp = dict(dp="on", dp_clip=0.05, dp_noise_mult=noise_mult,
              dp_delta=1e-5) if noise_mult else {}
    fl = FLConfig(num_clients=3, cohort_size=2, sampling="uniform", epochs=2,
                  local_batch=1, algorithm="fedshuffle", local_lr=0.05,
                  server_lr=0.5, seed=3, secagg=secagg, **dp)
    pipe = FederatedPipeline(TASK, Population.build(fl, sizes=TASK.sizes()), fl)
    x_star = jnp.asarray(TASK.optimum(), jnp.float32)

    def eval_fn(params):
        return {"dist": float(jnp.linalg.norm(params["x"] - x_star))}

    res = train(LOSS, {"x": jnp.zeros(3, jnp.float32)}, pipe, fl, ROUNDS,
                eval_fn=eval_fn, eval_every=ROUNDS, log_every=0,
                name=f"dp z={noise_mult}")
    last = res.metrics.rows[-1]
    clipped = float(np.mean([r.get("dp_clipped_frac", 0.0)
                             for r in res.metrics.rows]))
    return (last.get("dp_epsilon", float("inf")), last["eval_dist"], clipped)


def main():
    print(f"{ROUNDS} rounds, 2/3 clients per round, delta=1e-5\n")
    print(f"{'mechanism':28s} {'eps':>10s} {'|x - x*|':>10s} {'clip freq':>10s}")
    eps, dist, _ = run(None)
    print(f"{'non-private baseline':28s} {'inf':>10s} {dist:10.4f} {'-':>10s}")
    for z in (0.5, 1.0, 2.0):
        eps, dist, clipped = run(z)
        print(f"{f'dp  z={z}':28s} {eps:10.2f} {dist:10.4f} {clipped:10.2f}")
    eps, dist, clipped = run(1.0, secagg="pairwise")
    print(f"{'dp  z=1.0 + secagg':28s} {eps:10.2f} {dist:10.4f} {clipped:10.2f}")
    print("\nsmaller eps = stronger privacy; the noise it costs shows up as "
          "distance-to-optimum — pick z where the curve bends.")


if __name__ == "__main__":
    main()
